"""Serving throughput: wave batching vs ragged continuous batching.

Drives ``ServeEngine`` over a mixed-length request trace (short chat
requests interleaved with long-context ones — the serving analogue of the
paper's heterogeneous MPI job mix) and measures tokens/s plus p50/p99
per-token latency for both admission policies.  Wave batching is the
exclusive (non-co-scheduled) baseline: slots drain in lockstep and freed
slots idle until the whole wave finishes.  Continuous batching admits into
any freed slot at its own position and consumes prompts via chunked
prefill.

    PYTHONPATH=src python benchmarks/serve_throughput.py [--dry]

Emits BENCH_serve_throughput.json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.run / -m benchmarks.serve_throughput
    from .common import emit_json
except ImportError:  # python benchmarks/serve_throughput.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import Request, ServeEngine


def mixed_trace(*, n_short, n_long, short_prompt, long_prompt, max_new,
                vocab, seed=0):
    """Short chat requests interleaved with long-context ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    long_every = max(1, (n_short + n_long) // max(n_long, 1))
    for i in range(n_short + n_long):
        if n_long and i % long_every == 0:
            plen = long_prompt
            n_long -= 1
        else:
            plen = int(rng.integers(1, short_prompt + 1))
        reqs.append(Request(i, rng.integers(0, vocab, size=plen)
                            .astype(np.int32), max_new_tokens=max_new))
    return reqs


def run_mode(model, params, reqs, *, mode, slots, max_len):
    eng = ServeEngine(model, params, batch_slots=slots, max_len=max_len,
                      mode=mode)
    # warmup: compile every step shape this engine will hit
    eng.submit(Request(-1, np.asarray(reqs[0].prompt), max_new_tokens=2))
    eng.run()
    for r in reqs:
        eng.submit(r)
    lat = []  # per-token latency: tick duration attributed to its tokens
    t0 = time.perf_counter()
    while eng.queue or any(r is not None for r in eng.active):
        t1 = time.perf_counter()
        emitted = eng.step()
        dt = time.perf_counter() - t1
        lat.extend([dt / max(emitted, 1)] * emitted)
    wall = time.perf_counter() - t0
    done = [r for r in eng._finished if r.req_id >= 0]
    toks = sum(len(r.output) for r in done)
    # chunked prefill can emit first tokens inside step()'s admission —
    # they are counted by emitted, so lat covers every output token
    lat = np.asarray(lat) if lat else np.asarray([wall])
    return {
        "requests": len(done),
        "tokens": int(toks),
        "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "p50_token_latency_s": float(np.percentile(lat, 50)),
        "p99_token_latency_s": float(np.percentile(lat, 99)),
    }


def run(dry: bool = True, slots: int = 4, max_len: int = 128):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        trace_kw = dict(n_short=6, n_long=2, short_prompt=6, long_prompt=48,
                        max_new=4)
    else:
        trace_kw = dict(n_short=24, n_long=6, short_prompt=8, long_prompt=96,
                        max_new=8)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len}
    for mode in ("wave", "continuous"):
        reqs = mixed_trace(vocab=cfg.vocab_size, **trace_kw)
        r = run_mode(model, params, reqs, mode=mode, slots=slots,
                     max_len=max_len)
        results[mode] = r
        print(f"{mode:10s}: {r['tokens']} tok in {r['wall_s']:.2f}s "
              f"-> {r['tok_per_s']:.1f} tok/s, p50 "
              f"{r['p50_token_latency_s'] * 1e3:.1f}ms, p99 "
              f"{r['p99_token_latency_s'] * 1e3:.1f}ms")
    speedup = (results["continuous"]["tok_per_s"]
               / max(results["wave"]["tok_per_s"], 1e-9))
    results["continuous_speedup"] = speedup
    print(f"continuous/wave speedup: {speedup:.2f}x")
    # dry (CI smoke) runs must not clobber the tracked full-trace snapshot
    emit_json("serve_throughput_dry" if dry else "serve_throughput", results)
    # the qualitative claim this benchmark gates: continuous batching beats
    # wave batching on a mixed-length trace (acceptance asks for >= 2x)
    assert speedup >= 1.5, f"continuous batching only {speedup:.2f}x wave"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
