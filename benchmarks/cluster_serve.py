"""Cluster serving: replica-scaling throughput + a chaos recovery gate.

Part 1 (scaling) drives the same request trace through a
``ClusterRouter`` fronting N in {1, 2, 4} ``ServeEngine`` replicas
(spread placement) and reports **aggregate tokens/s** per pool size.
Replicas share model/params, so the compiled steps dedupe through the
``runtime.steps`` module LRU — scaling measures router + engine work,
not recompilation.

Part 2 (chaos) is the robustness twin the perf number cannot ship
without: the identical trace runs once fault-free and once under a
seeded kill + rejoin schedule (one of three replicas dies mid-run and
later rejoins).  The gate asserts, in-process and machine-independent:

* every submitted request completes (zero lost to the fault),
* every output is **bitwise-identical** to the fault-free run
  (deterministic replay recovery: re-prefill of prompt + already-emitted
  tokens under PR 3's position-folded sampling),
* at least one request actually exercised recovery,
* the surviving replicas' page pools drain to zero (no leaked pages from
  requests that died mid-flight elsewhere),
* brown-out honors the SLO tiers: gold p99 TTFT <= free p99 TTFT while
  capacity is degraded (weighted shedding protects gold).

The chaos run is additionally served with full telemetry on: its Chrome
trace-event JSON is written to ``artifacts/chaos_trace.json``
(Perfetto-viewable — the fence, REPLAY spans, and re-placement are all
visible on the router track), the flight recorder dumps on the fence,
and the gate asserts the recovery left >= 1 REPLAY span with every span
closed and the trace structurally valid.

    PYTHONPATH=src python benchmarks/cluster_serve.py [--dry]

Emits BENCH_cluster_serve[_dry].json via ``common.emit_json``;
``scripts/check_bench.py`` gates the dry numbers against
``benchmarks/baselines/``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.cluster_serve
    from .common import emit_json
except ImportError:  # python benchmarks/cluster_serve.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.cluster import ClusterRouter
from repro.runtime.fault import FaultEvent, ReplicaFaultInjector
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)
from repro.runtime.telemetry import Telemetry, validate_chrome_trace

TENANT_WEIGHTS = {"gold": 3.0, "free": 1.0}


def trace(*, n, max_new, vocab, seed=0):
    """Mixed trace: greedy and seeded-sampled requests, gold/free tiers
    interleaved (1 gold : 2 free) so brown-out shedding has tiers to
    arbitrate."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 12))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if i % 2 else 0.0, seed=11)
        reqs.append(Request(i, prompt, max_new_tokens=max_new, sampling=sp,
                            tenant="gold" if i % 3 == 0 else "free"))
    return reqs


def fresh(reqs):
    """Requests are mutated by serving; each run gets its own copies."""
    return [dataclasses.replace(r, prompt=np.asarray(r.prompt), output=[])
            for r in reqs]


def run_pool(model, params, reqs, *, n_replicas, slots, max_len,
             injector=None, cache="dense", telemetry=None):
    def make_engine(rid):
        return ServeEngine(model, params, ServeConfig(
            batch_slots=slots, max_len=max_len, cache=cache, page_size=8,
            prefix_cache=False, policy="drf-fair",
            tenant_weights=TENANT_WEIGHTS))

    router = ClusterRouter(make_engine, n_replicas, policy="spread",
                           tenant_weights=TENANT_WEIGHTS,
                           injector=injector, telemetry=telemetry)
    handles = [router.submit(r) for r in reqs]
    t0 = time.perf_counter()
    done = router.run(max_ticks=20_000)
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    out = {
        "requests": len(done), "tokens": int(toks), "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "all_completed": bool(
            len(done) == len(reqs)
            and all(r.finish_reason != "failed" for r in done)),
        "outputs": {r.req_id: list(r.output) for r in done},
        "stats": router.stats(),
    }
    ttft = {"gold": [], "free": []}
    for h in handles:
        t = h.metrics().get("ttft_s")
        if t is not None:
            ttft[h.req.tenant].append(t)
    for tier, vals in ttft.items():
        if vals:
            out[f"{tier}_p99_ttft_s"] = float(np.percentile(vals, 99))
    out["pool_drained"] = all(
        rh.engine.kv.pool.in_use == 0
        for rh in router.replicas
        if rh.engine is not None and rh.engine.kv is not None)
    return out


def run(dry: bool = True, slots: int = 2, max_len: int = 96):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    trace_kw = (dict(n=12, max_new=16) if dry
                else dict(n=32, max_new=48))
    reqs = trace(vocab=cfg.vocab_size, **trace_kw)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len,
               "tenant_weights": TENANT_WEIGHTS}

    # warm the compiled steps so Part 1 times serving, not jit
    run_pool(model, params, fresh(reqs[:2]), n_replicas=1, slots=slots,
             max_len=max_len)
    run_pool(model, params, fresh(reqs[:2]), n_replicas=1, slots=slots,
             max_len=max_len, cache="paged")

    # ---- Part 1: replica scaling ------------------------------------
    for n in (1, 2, 4):
        r = run_pool(model, params, fresh(reqs), n_replicas=n,
                     slots=slots, max_len=max_len)
        results[f"tok_per_s_{n}"] = r["tok_per_s"]
        results[f"all_completed_{n}"] = r["all_completed"]
        print(f"scaling N={n}: {r['tokens']} tok in {r['wall_s']:.2f}s "
              f"-> {r['tok_per_s']:.1f} tok/s")

    # ---- Part 2: chaos vs fault-free twin ---------------------------
    # paged engines so the gate also covers page recovery/refcounts;
    # kill replica 1 early (mid-prefill/decode for the first batch),
    # rejoin it before the run ends
    horizon = 6 if dry else 12
    injector = ReplicaFaultInjector([
        FaultEvent(horizon, "kill", 1),
        FaultEvent(horizon * 5, "rejoin", 1),
    ])
    clean = run_pool(model, params, fresh(reqs), n_replicas=3,
                     slots=slots, max_len=max_len, cache="paged")
    # the chaos run is fully traced: the Chrome-trace JSON (Perfetto-
    # viewable) lands in artifacts/, the armed flight recorder dumps on
    # the fence, and the gate counts the REPLAY spans the recovery opened
    tm = Telemetry(trace=True, flight=512, flight_dir="artifacts")
    chaos = run_pool(model, params, fresh(reqs), n_replicas=3,
                     slots=slots, max_len=max_len, cache="paged",
                     injector=injector, telemetry=tm)
    st = chaos["stats"]
    results["chaos"] = {
        k: chaos[k] for k in ("requests", "tokens", "wall_s", "tok_per_s",
                              "all_completed", "pool_drained")
        if k in chaos}
    results["chaos"].update(
        recoveries=st["recoveries"], replicas_lost=st["replicas_lost"],
        brownout_ticks=st["brownout_ticks"], failed=st["failed"])
    trace_path = tm.write_trace(os.path.join("artifacts",
                                             "chaos_trace.json"))
    v = validate_chrome_trace(trace_path)
    results["chaos"].update(
        replay_spans=sum(1 for e in tm.trace.events
                         if e.get("ph") == "B" and e.get("name") == "REPLAY"),
        trace_events=tm.trace.total,
        spans_balanced=not tm.trace.open_spans(),
        trace_valid=bool(v["balanced"]),
        flight_dumps=list(tm.flight_dumps))
    print(f"chaos trace: {tm.trace.total} events -> {trace_path}, "
          f"{results['chaos']['replay_spans']} REPLAY spans, "
          f"flight dumps {tm.flight_dumps}")
    results["chaos_bitwise_identical"] = bool(
        chaos["outputs"] == clean["outputs"])
    for tier in ("gold", "free"):
        key = f"{tier}_p99_ttft_s"
        if key in chaos:
            results[f"chaos_{key}"] = chaos[key]
    results["gold_p99_ttft_bounded"] = bool(
        results.get("chaos_gold_p99_ttft_s", 0.0)
        <= results.get("chaos_free_p99_ttft_s", float("inf")))
    print(f"chaos: {st['replicas_lost']} replica lost, "
          f"{st['recoveries']} recoveries, bitwise identical "
          f"{results['chaos_bitwise_identical']}, gold p99 ttft "
          f"{results.get('chaos_gold_p99_ttft_s', 0) * 1e3:.0f}ms vs free "
          f"{results.get('chaos_free_p99_ttft_s', 0) * 1e3:.0f}ms")

    emit_json("cluster_serve_dry" if dry else "cluster_serve", results)
    # headline claims, asserted in-process (machine-independent):
    assert all(results[f"all_completed_{n}"] for n in (1, 2, 4)), \
        "a fault-free pool dropped requests"
    assert chaos["all_completed"], \
        "requests were lost to the injected replica kill"
    assert results["chaos_bitwise_identical"], \
        "recovered outputs diverged from the fault-free run"
    assert st["recoveries"] >= 1, \
        "the kill schedule recovered nothing — the gate tested nothing"
    assert chaos["pool_drained"], \
        "surviving replicas leaked KV pages after recovery"
    assert results["gold_p99_ttft_bounded"], \
        "brown-out shedding failed to protect the gold tier"
    # observability gates: the recovery left a visible trail — at least
    # one REPLAY span in the Chrome trace, every span closed, and the
    # trace validates end-to-end
    assert results["chaos"]["replay_spans"] >= 1, \
        "chaos run traced no REPLAY spans"
    assert results["chaos"]["spans_balanced"], \
        "chaos run left trace spans open"
    assert results["chaos"]["trace_valid"], "chaos trace failed validation"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
