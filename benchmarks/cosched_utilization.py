"""Paper Figs 8-11 — co-scheduled vs exclusive (traditional HPC) execution.

Ten MiniFE-like jobs on a 2x8-host cluster, run (a) exclusively (one gang
at a time, the paper's "non-co-scheduled" HPC baseline) and (b) co-scheduled
by Scylla through DRF offers.  The paper reports ~2x faster completion for
the same work, +60% CPU and +44% memory utilization; we report chip
utilization and makespan from the same discrete-event engine the tests use.
"""
from __future__ import annotations

from repro.core import ClusterSpec, JobSpec, Simulator

from .common import emit, save_artifact


def run():
    spec = ClusterSpec(n_pods=2, hosts_per_pod=8)
    results = {}
    for co in (False, True):
        sim = Simulator(spec, co_schedule=co)
        for i in range(10):
            sim.submit_at(0.0, JobSpec(f"minife{i}", "internlm2-1.8b",
                                       "train_4k", chips=16,
                                       policy="spread", steps=300))
        results[co] = sim.run()
    excl, cos = results[False], results[True]
    speedup = excl["makespan"] / cos["makespan"]
    util_gain = (cos["avg_utilization"] - excl["avg_utilization"]) \
        / max(excl["avg_utilization"], 1e-9)
    emit("fig8_11_exclusive_makespan", excl["makespan"] * 1e6,
         f"util={excl['avg_utilization'] * 100:.0f}%")
    emit("fig8_11_cosched_makespan", cos["makespan"] * 1e6,
         f"util={cos['avg_utilization'] * 100:.0f}%")
    emit("fig8_11_speedup", speedup * 1e6,
         f"paper~2x; ours={speedup:.2f}x util_gain={util_gain * 100:.0f}%"
         f" (paper +60%CPU/+44%mem)")
    assert speedup > 1.5, "co-scheduling must beat exclusive (paper ~2x)"
    assert util_gain > 0.5, "utilization gain must be large (paper +60%)"
    save_artifact("bench_fig8_11.json", {
        "exclusive": {k: v for k, v in excl.items() if k != "jobs"},
        "cosched": {k: v for k, v in cos.items() if k != "jobs"},
        "speedup": speedup, "util_gain": util_gain,
        "paper": {"speedup": "~2x", "cpu_util_gain": 0.60,
                  "mem_util_gain": 0.44},
    })


if __name__ == "__main__":
    run()
