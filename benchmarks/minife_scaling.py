"""Paper Fig 6 — MiniFE (CPU+memory-intensive) vs cluster size.

Analogue: a memory-bound training job (granite-20b train_4k profile, the
most memory-bound dense train cell) spread over 2..6 hosts.  The paper
observes runtime falling with added nodes as the container overhead is
amortized — here aggregate HBM bandwidth grows with chips.
"""
from __future__ import annotations

from repro.core import hw
from repro.core.costmodel import PlacementView, analytic_profile, step_time

from .common import emit, load_dryrun_rows, save_artifact


def run():
    arch = "granite-20b"
    profile, infeed = analytic_profile(arch, "train_4k")
    # prefer exact dry-run numbers when the artifact exists
    for r in load_dryrun_rows():
        if (r.get("arch") == arch and r.get("shape") == "train_4k"
                and r.get("mesh") == "single" and not r.get("error")
                and r.get("tag", "baseline") == "baseline"):
            from repro.core.jobs import RooflineProfile

            profile = RooflineProfile(
                flops=r["hlo_flops"], hbm_bytes=r["hlo_bytes"],
                ici_bytes=r["collective_bytes"])
            break
    rows = []
    prev = None
    for hosts in (2, 3, 4, 5, 6):
        chips = hosts * hw.CHIPS_PER_HOST
        view = PlacementView(chips=chips, n_hosts=hosts, n_pods=1)
        t = step_time(profile, infeed, view)
        rows.append({"hosts": hosts, **t})
        emit(f"fig6_minife_hosts{hosts}", t["step_s"] * 1e6,
             f"bottleneck={t['bottleneck']}")
        if prev is not None:
            assert t["step_s"] < prev, "must scale down with more nodes"
        prev = t["step_s"]
    save_artifact("bench_fig6.json", rows)


if __name__ == "__main__":
    run()
