"""Sharded decode vs the single-device engine: identity + throughput.

Forces 8 host devices (the env vars must land before jax imports, so
this benchmark always runs as its own process — ``scripts/check_bench.py``
and ``scripts/ci.sh`` both launch it that way) and drives the same
mixed greedy/seeded-sampled paged trace through three engines: the
single-device baseline, a TP-2 mesh ``(1, 2)``, and a 2-host x TP-2
mesh ``(2, 2)`` whose data axis shards the decode slots and splits the
KV pool into per-host sub-pools.

The structural gate is the tentpole invariant: both sharded engines'
token streams must be BITWISE-identical to the baseline (sampled
trajectories only match when every logit is bit-exact), and the 2-host
engine's offer must advertise a per-host page split that sums to the
aggregate.  Throughput is recorded per engine for trend tracking only —
on a forced-host-device CPU mesh the collectives are emulated, so the
sharded tok/s is a noise floor, not a speedup claim.

    PYTHONPATH=src python benchmarks/sharded_decode.py [--dry]

Emits BENCH_sharded_decode[_dry].json via ``common.emit_json``.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.run / -m benchmarks.sharded_decode
    from .common import emit_json, request_latency_stats
except ImportError:  # python benchmarks/sharded_decode.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json, request_latency_stats
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)


def mixed_trace(n_req, max_new, vocab, seed=7):
    """Alternating greedy / seeded-sampled requests (the identity check
    needs sampled rows: they only reproduce when logits are bit-exact)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        prompt = rng.integers(1, vocab,
                              size=int(rng.integers(3, 24))).astype(np.int32)
        sp = (SamplingParams() if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=20, seed=i))
        reqs.append(Request(i, prompt, max_new_tokens=max_new, sampling=sp))
    return reqs


def run_engine(model, params, reqs, *, reps, **cfg_kw):
    eng = ServeEngine(model, params, ServeConfig(**cfg_kw))
    # warmup rep compiles every step shape, then best-of-reps walls
    wall = float("inf")
    for rep in range(reps + 1):
        for r in reqs:
            eng.submit(Request(r.req_id, r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens,
                               sampling=r.sampling))
        t0 = time.perf_counter()
        done = eng.run()
        if rep:  # rep 0 pays the compiles
            wall = min(wall, time.perf_counter() - t0)
    toks = sum(len(r.output) for r in done)
    out = {"requests": len(done), "tokens": int(toks), "wall_s": wall,
           "tok_per_s": toks / max(wall, 1e-9)}
    out.update(request_latency_stats(done))
    return out, {r.req_id: tuple(r.output) for r in done}, eng


def run(dry: bool = True, slots: int = 4, max_len: int = 64,
        page_size: int = 16):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64, d_model=64,
                              num_heads=4, num_kv_heads=2, head_dim=16,
                              d_ff=128)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, q_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    n_req, max_new, reps = (8, 6, 1) if dry else (24, 12, 3)
    reqs = mixed_trace(n_req, max_new, cfg.vocab_size)

    results = {"slots": slots, "max_len": max_len, "page_size": page_size,
               "requests": n_req, "devices": jax.device_count()}
    outs = {}
    for name, shape in (("unsharded", None), ("tp2", (1, 2)),
                        ("dp2tp2", (2, 2))):
        r, outs[name], eng = run_engine(
            model, params, reqs, reps=reps, batch_slots=slots,
            max_len=max_len, cache="paged", page_size=page_size,
            mesh_shape=shape)
        results[name] = r
        print(f"{name:9s}: {r['tokens']} tok in {r['wall_s']:.2f}s -> "
              f"{r['tok_per_s']:.1f} tok/s")
        if name == "dp2tp2":
            off = eng.offer()
            by_host = off["free_pages_by_host"]
            results["offer_by_host"] = by_host
            results["offer_by_host_sums"] = \
                bool(sum(by_host) == off["free_pages"])
    results["tp2_bitwise_identical"] = bool(outs["tp2"] == outs["unsharded"])
    results["dp2tp2_bitwise_identical"] = \
        bool(outs["dp2tp2"] == outs["unsharded"])
    print(f"tp2 bitwise={results['tp2_bitwise_identical']} "
          f"dp2tp2 bitwise={results['dp2tp2_bitwise_identical']} "
          f"offer by host={results.get('offer_by_host')}")
    emit_json("sharded_decode_dry" if dry else "sharded_decode", results)
    assert results["tp2_bitwise_identical"], \
        "TP-2 sharded decode diverged from the single-device engine"
    assert results["dp2tp2_bitwise_identical"], \
        "2-host TP-2 sharded decode diverged from the single-device engine"
    assert results["offer_by_host_sums"], results
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace, 1 timed rep")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len,
        page_size=args.page_size)


if __name__ == "__main__":
    main()
