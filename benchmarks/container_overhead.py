"""Paper Fig 5 — container deployment overhead vs cluster size.

TPU adaptation (DESIGN.md §2 note 3): container creation becomes XLA
compile + weight distribution.  Compile time is measured for real (a
reduced-config jit on this host); weight distribution parallelizes across
hosts exactly like the paper's per-host container pulls.  We report the
startup overhead as a fraction of a short job's total runtime, for cluster
sizes 2..6 hosts — the paper observes ~20% for <16 containers on >=4 hosts,
decreasing with cluster size.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import hw
from repro.core.costmodel import analytic_profile, step_time, PlacementView
from repro.models import LM, RuntimeKnobs
from repro.optim import AdamWConfig
from repro.runtime.steps import init_train_state, make_train_step

from .common import emit, save_artifact


def measure_compile_seconds() -> float:
    """Ground the compile-cost model with a real jit compile."""
    model = LM(get_config("internlm2-1.8b", smoke=True),
               RuntimeKnobs(cache_dtype=jnp.float32))
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig()))
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    t0 = time.perf_counter()
    step.lower(state, batch).compile()
    return time.perf_counter() - t0


PER_SHARD_SETUP_S = 1.5  # weight-shard load + runtime spin-up per chip


def run():
    compile_s = measure_compile_seconds()
    emit("fig5_measured_compile", compile_s * 1e6,
         "smoke-model XLA compile (container-create analogue)")
    arch = "internlm2-1.8b"
    profile, infeed = analytic_profile(arch, "train_4k")
    # Paper setup: a FIXED job (32 ranks) deployed on 2..6 hosts — job
    # runtime stays constant; per-host container instantiation parallelizes.
    chips = 12
    rows = []
    steps = 20  # a short mini-app-like job (paper: minutes-long MPI apps)
    view = PlacementView(chips=chips, n_hosts=6, n_pods=1)
    runtime = steps * step_time(profile, infeed, view)["step_s"]
    for hosts in (2, 3, 4, 5, 6):
        shards_per_host = -(-chips // hosts)  # ceil
        startup = hw.COMPILE_BASE_S + shards_per_host * PER_SHARD_SETUP_S
        frac = startup / (startup + runtime)
        rows.append({"hosts": hosts, "startup_s": startup,
                     "runtime_s": runtime, "overhead_frac": frac})
        emit(f"fig5_overhead_hosts{hosts}", startup * 1e6,
             f"overhead={frac * 100:.1f}% of short-job runtime")
    assert rows[0]["overhead_frac"] > rows[-1]["overhead_frac"], \
        "overhead must fall as the cluster grows (paper Fig 5 trend)"
    # paper: ~20% overhead for clusters >= 4 hosts with < 16 containers
    tail = [r["overhead_frac"] for r in rows if r["hosts"] >= 4]
    assert all(0.05 < f < 0.45 for f in tail), tail
    save_artifact("bench_fig5.json", {"compile_measured_s": compile_s,
                                      "rows": rows})


if __name__ == "__main__":
    run()
