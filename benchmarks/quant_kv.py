"""Quantized paged KV (int8) vs the bf16 paged baseline.

Drives two paged engines over the same shared-prefix trace: the baseline
stores K/V pages at bf16 (``RuntimeKnobs.cache_dtype``), the quantized
engine stores int8 pages plus per-token/per-head f32 scales
(``ServeConfig.kv_dtype="int8"``) and dequantizes at read inside the
attention kernels.  Tokens are NOT expected to match bitwise — int8 is a
lossy cache — so each engine's outputs are only checked for completion;
the accuracy contract lives in tests/test_quant_kv.py.

Reported per engine: tokens/s, TTFT/TPOT percentiles, KV HBM bytes
reserved, prefix-hit counters.  The headline gates:

* ``kv_bytes_ratio`` — bf16 bytes / int8 bytes.  Machine-independent and
  analytic: 2·D / (D + 4) per row (head dim D pays 1 byte/elem plus a
  4-byte scale per row), ≈ 1.88 at D = 64 — gated at >= 1.7.  That is
  the "~2x pages per HBM byte" acceptance claim: the same pool byte
  budget holds ~2x the pages.
* ``speed_ratio`` — int8 tokens/s / bf16 tokens/s.  On a real
  accelerator the halved HBM stream pays for the dequant multiply
  (floor 1.0); dry CPU runs have no HBM advantage and pay the extra
  elementwise work, so the dry floor only guards against pathological
  slowdowns.

    PYTHONPATH=src python benchmarks/quant_kv.py [--dry]

Emits BENCH_quant_kv[_dry].json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

try:  # python -m benchmarks.run / -m benchmarks.quant_kv
    from .common import emit_json
    from .paged_serve import run_engine, shared_prefix_trace
except ImportError:  # python benchmarks/quant_kv.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json
    from paged_serve import run_engine, shared_prefix_trace
from repro.configs import get_config
from repro.models import LM, RuntimeKnobs

import numpy as np


def run(dry: bool = True, slots: int = 4, max_len: int = 128,
        page_size: int = 16):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    # bf16 cache baseline: the production storage dtype the int8 pool
    # competes with (the f32 test knob would flatter the ratio)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.bfloat16))
    params = model.init(jax.random.PRNGKey(0))

    if dry:
        trace_kw = dict(n_req=8, prefix_len=64, tail_max=4, n_long=2,
                        long_prompt=96, max_new=4)
    else:
        trace_kw = dict(n_req=24, prefix_len=64, tail_max=8, n_long=4,
                        long_prompt=112, max_new=8)
    num_pages = (slots * max_len // page_size) // 2 + 1
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len,
               "page_size": page_size, "num_pages": num_pages}
    for name, kw in (("bf16", {}), ("int8", dict(kv_dtype="int8"))):
        reqs = shared_prefix_trace(vocab=cfg.vocab_size, **trace_kw)
        warm = (np.arange(2 * page_size) % cfg.vocab_size).astype(np.int32)
        r, outs = run_engine(
            model, params, reqs, warm_prompt=warm, batch_slots=slots,
            max_len=max_len, prefill_chunk=page_size, cache="paged",
            page_size=page_size, num_pages=num_pages, **kw)
        r["completed_all"] = (len(outs) == trace_kw["n_req"]
                              and all(len(o) == trace_kw["max_new"]
                                      for o in outs.values()))
        results[name] = r
        print(f"{name:5s}: {r['tokens']} tok in {r['wall_s']:.2f}s -> "
              f"{r['tok_per_s']:.1f} tok/s, KV reserved "
              f"{r['kv_reserved_bytes'] / 1024:.0f} KiB, "
              f"prefix hits {r['prefix_hits']}")

    bytes_ratio = (results["bf16"]["kv_reserved_bytes"]
                   / max(results["int8"]["kv_reserved_bytes"], 1))
    speed = (results["int8"]["tok_per_s"]
             / max(results["bf16"]["tok_per_s"], 1e-9))
    # analytic density: a bf16 row costs 2D bytes, an int8 row D bytes
    # plus one f32 scale — 2D/(D+4), ≈ 1.88 at the production D = 64
    # and 1.6 at this smoke model's D = 16 (the scale overhead is a
    # fixed 4 bytes/row, so density *improves* with head dim)
    analytic = 2 * cfg.head_dim / (cfg.head_dim + 4)
    results["kv_bytes_ratio"] = bytes_ratio
    results["kv_bytes_ratio_analytic"] = analytic
    results["speed_ratio"] = speed
    print(f"int8 pools hold {bytes_ratio:.2f}x the pages per HBM byte "
          f"(analytic {analytic:.2f}x at D={cfg.head_dim}) at "
          f"{speed:.2f}x bf16 throughput")
    emit_json("quant_kv_dry" if dry else "quant_kv", results)
    # acceptance gates: pages-per-byte at the analytic bound
    # (machine-independent — the reservation is a pure function of
    # shapes); throughput parity on real HBM (full runs) with a loose
    # dry floor for CPU-only CI samples
    assert bytes_ratio >= 0.95 * analytic, \
        f"int8 pools only {bytes_ratio:.2f}x denser " \
        f"(analytic {analytic:.2f}x)"
    min_speed = 0.5 if dry else 1.0
    assert speed >= min_speed, \
        f"int8 engine {speed:.2f}x bf16 tokens/s (floor {min_speed})"
    assert results["int8"]["completed_all"], "int8 engine dropped requests"
    assert results["int8"]["prefix_hits"] > 0, \
        "prefix cache never hit under quantization"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len,
        page_size=args.page_size)


if __name__ == "__main__":
    main()
