"""Roofline-gated kernel counters: each serving kernel's achieved
fraction of its analytic roofline, tracked and gated across PRs.

For the three hot decode kernels — **dense decode** (``serve``),
**paged decode** (``paged_serve``), **speculative verify**
(``spec_serve``) — this lowers the exact compiled step the engine
dispatches, feeds its HLO through ``launch/roofline.py``'s static
analyzer (FLOPs + HBM traffic per step), converts the counts into the
analytic per-step roofline bound, and divides by the measured per-step
wall time:

    achieved_fraction = roofline_step_s / measured_step_s

The fraction is a *machine-tracked ratio*: the numerator is a pure
function of the HLO (stable by construction), the denominator moves only
when the kernel's real speed moves — so ``scripts/check_bench.py`` gates
it exactly like a throughput rate (the ReFrame roofline regression-test
idiom).  The analyzer's counters are additionally bound-checked here:
every kernel must report positive FLOPs and HBM bytes, and the
fraction must be positive — an analyzer regression (HLO format drift,
a kernel falling out of the fusion the counts assume) fails in-process
before any number is recorded.

Fractions land in the benchmark registry as
``kernel_roofline_fraction{kernel=...}`` gauges and per-section wall
time comes from ``common.section`` (the registry is the stopwatch).

    PYTHONPATH=src python benchmarks/kernel_roofline.py [--dry]

Emits BENCH_kernel_roofline[_dry].json via ``common.emit_json``.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.kernel_roofline
    from .common import emit_json, registry, section, section_times
except ImportError:  # python benchmarks/kernel_roofline.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json, registry, section, section_times
from repro.configs import get_config
from repro.launch.roofline import analyze_hlo, roofline
from repro.models import LM, RuntimeKnobs
from repro.runtime.steps import compiled_step

SLOTS = 4
PAGE_SIZE = 16
DRAFT_K = 3


def _model(max_len, **knob_over):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32, **knob_over))
    return model, model.init(jax.random.PRNGKey(0))


def _measure(fn, params, caches, args, iters, thread_last=False):
    """Best-of-iters per-call wall time.  The step donates its caches,
    so each call chains the previous call's output caches back in —
    decode-in-place, exactly as the engine drives it.  ``thread_last``
    (the buffered prefill step) additionally chains the gather buffer:
    the step's third output replaces the last positional arg."""
    def call(caches, args):
        res = fn(params, caches, *args)
        if thread_last:
            out, caches, buf = res
            return out, caches, args[:-1] + (buf,)
        out, caches = res
        return out, caches, args
    out, caches, args = call(caches, args)  # warmup + donate the init
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out, caches, args = call(caches, args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, caches


def _kernel_case(model, params, kind, *, max_len, iters):
    """(analysis, measured_s) for one serving kernel at a mid-stream
    decode position — the steady-state shape the engine spends its
    time in."""
    B = SLOTS
    pos_val = max_len // 2
    pos = jnp.asarray(np.full(B, pos_val, np.int32))
    rng = np.random.default_rng(0)
    max_pages = max_len // PAGE_SIZE
    # every slot fully mapped onto distinct pages (page 0 = null)
    table = (1 + np.arange(B * max_pages, dtype=np.int32)
             .reshape(B, max_pages))
    thread_last = False
    if kind == "serve":
        caches = model.init_cache(B, max_len)
        step = compiled_step(model, "serve")
        args = (jnp.asarray(rng.integers(1, 64, (B, 1)).astype(np.int32)),
                pos)
    elif kind == "paged_serve":
        num_pages = B * max_pages + 1  # + the null page
        caches = model.init_cache_paged(num_pages, PAGE_SIZE)
        step = compiled_step(model, "paged_serve", page_size=PAGE_SIZE)
        args = (jnp.asarray(rng.integers(1, 64, (B, 1)).astype(np.int32)),
                pos, jnp.asarray(table))
    elif kind == "paged_prefill_chunk_buf":
        # one slot's mid-prompt chunk through the buffered XLA prefill:
        # the page-table read path plus the dense slot-view insert
        num_pages = B * max_pages + 1
        caches = model.init_cache_paged(num_pages, PAGE_SIZE)
        buf = model.init_cache(1, max_len)
        step = compiled_step(model, kind, page_size=PAGE_SIZE)
        chunk = rng.integers(1, 64, (1, PAGE_SIZE)).astype(np.int32)
        args = (jnp.asarray(chunk), jnp.int32(0), jnp.int32(pos_val),
                jnp.asarray(table), buf)
        thread_last = True
    elif kind == "spec_serve":
        caches = model.init_cache(B, max_len)
        step = compiled_step(model, "spec_serve", draft_len=DRAFT_K)
        feed = rng.integers(1, 64, (B, DRAFT_K + 1)).astype(np.int32)
        args = (jnp.asarray(feed), pos)
    else:
        raise ValueError(kind)
    hlo = step.lower(params, caches, *args).compile().as_text()
    analysis = analyze_hlo(hlo)
    measured_s, caches = _measure(step, params, caches, args, iters,
                                  thread_last=thread_last)
    del caches
    return analysis, measured_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    max_len = 64 if args.dry else 128
    iters = 10 if args.dry else 30
    model, params = _model(max_len)
    # quantized decode: same step kind, int8 pools + f32 scale pools,
    # dequantized at read — the HBM stream the quantization halves
    quant, _ = _model(max_len, kv_quant="int8")
    # paged split-K: a Pallas kernel (interpret mode off-TPU), one page
    # per split at max_len/PAGE_SIZE = 4 (dry) / 8 pages
    splitk, _ = _model(max_len, use_pallas=True,
                       decode_splits=min(4, max_len // PAGE_SIZE))

    cases = [("dense_decode", "serve", model),
             ("paged_decode", "paged_serve", model),
             ("quant_decode", "paged_serve", quant),
             ("paged_prefill", "paged_prefill_chunk_buf", model),
             ("paged_splitk", "paged_serve", splitk),
             ("spec_verify", "spec_serve", model)]
    results = {}
    frac_gauge = registry().gauge(
        "kernel_roofline_fraction",
        "achieved fraction of the analytic roofline", ("kernel",))
    for name, kind, mdl in cases:
        with section(name):
            analysis, measured_s = _kernel_case(mdl, params, kind,
                                                max_len=max_len,
                                                iters=iters)
        terms = roofline(analysis["flops"], analysis["hbm_bytes"],
                         analysis, n_devices=1)
        frac = terms["step_s"] / max(measured_s, 1e-12)
        # analyzer bound-checks: a kernel with zero counted FLOPs or
        # bytes means the HLO walk no longer sees the compute — fail
        # loudly before recording a meaningless fraction
        assert analysis["flops"] > 0, (name, "flops")
        assert analysis["hbm_bytes"] > 0, (name, "hbm_bytes")
        assert frac > 0, (name, frac)
        frac_gauge.labels(kernel=name).set(frac)
        results[name] = {
            "flops": analysis["flops"],
            "hbm_bytes": analysis["hbm_bytes"],
            "bottleneck": terms["bottleneck"],
            "roofline_step_s": terms["step_s"],
            "measured_step_s": measured_s,
            "achieved_fraction": frac,
        }
        print(f"{name}: {analysis['flops']:.3g} flops, "
              f"{analysis['hbm_bytes']:.3g} HBM bytes, "
              f"bound {terms['step_s'] * 1e6:.2f}us "
              f"({terms['bottleneck']}), measured "
              f"{measured_s * 1e6:.1f}us -> fraction {frac:.3g}")

    # spec verify amortizes: its step scores DRAFT_K+1 tokens, so its
    # per-TOKEN bound is tighter than dense decode's whenever the
    # fraction ratio beats 1/(k+1) — recorded, not gated (machine lore)
    results["spec_tokens_per_step"] = DRAFT_K + 1
    results["max_len"] = max_len
    results["slots"] = SLOTS
    results["sections"] = section_times()
    emit_json("kernel_roofline_dry" if args.dry else "kernel_roofline",
              results)


if __name__ == "__main__":
    main()
