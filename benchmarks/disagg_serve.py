"""Disaggregated serving: prefill/decode split vs unified, chaos
mid-handoff, and elastic-autoscaler churn.

Part 1 (split) drives a **prefill-heavy** trace (long prompts, short
continuations — the regime disaggregation targets) through a unified
2-replica pool and through a 1 prefill + 1 decode ``DisaggRouter`` with
the same total slots, and reports tokens/s + TTFT percentiles for each.
The outputs must be bitwise-identical across the split: sampling folds
(request key, absolute position), never slot or replica.

Part 2 (chaos) kills a prefill replica at the instant handoffs from it
sit in transit (paged chains still in the dying pool).  The run is
fully traced with the flight recorder armed; the gate asserts zero lost
requests, >= 1 replay recovery, outputs bitwise-identical to the
fault-free disagg twin, surviving pools drained, HANDOFF spans in the
Chrome trace, and the fence's flight dump carrying the in-transit
handoff snapshot.

Part 3 (churn) runs the same stack under an elastic ``Autoscaler``
(cold DOWN spares rejoin under backlog) and, at thousands-of-requests
scale, the ``core.simulator.ServeChurnSim`` driving the *same*
autoscaler against a fake cluster: zero lost requests, min/max bounds
respected, both scale directions exercised, scale events visible as
SCALE_* telemetry spans.

    PYTHONPATH=src python benchmarks/disagg_serve.py [--dry]

Emits BENCH_disagg_serve[_dry].json via ``common.emit_json``;
``scripts/check_bench.py`` gates the dry numbers against
``benchmarks/baselines/``.
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

try:  # python -m benchmarks.disagg_serve
    from .common import emit_json
except ImportError:  # python benchmarks/disagg_serve.py
    sys.path.insert(0, os.path.dirname(__file__))
    from common import emit_json
from repro.configs import get_config
from repro.core.simulator import ServeChurnSim
from repro.models import LM, RuntimeKnobs
from repro.runtime.autoscale import Autoscaler
from repro.runtime.cluster import ClusterRouter
from repro.runtime.disagg import DisaggRouter
from repro.runtime.serve import (Request, SamplingParams, ServeConfig,
                                 ServeEngine)
from repro.runtime.telemetry import Telemetry, validate_chrome_trace

_PAGED = dict(cache="paged", page_size=8, prefix_cache=False)


def trace(*, n, max_new, vocab, seed=0):
    """Prefill-heavy: prompts 16-32 tokens, short continuations, mixed
    greedy + seeded-sampled."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(16, 33))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        sp = SamplingParams(temperature=0.8 if i % 2 else 0.0, seed=11)
        reqs.append(Request(i, prompt, max_new_tokens=max_new,
                            sampling=sp,
                            tenant="gold" if i % 3 == 0 else "free"))
    return reqs


def fresh(reqs):
    return [dataclasses.replace(r, prompt=np.asarray(r.prompt), output=[])
            for r in reqs]


def run_router(router, reqs):
    handles = [router.submit(r) for r in reqs]
    t0 = time.perf_counter()
    done = router.run(max_ticks=20_000)
    wall = time.perf_counter() - t0
    return summarize(router, handles, done, len(reqs), wall)


def summarize(router, handles, done, n_submitted, wall):
    toks = sum(len(r.output) for r in done)
    ttft = [h.metrics().get("ttft_s") for h in handles]
    ttft = [t for t in ttft if t is not None]
    out = {
        "requests": len(done), "tokens": int(toks), "wall_s": wall,
        "tok_per_s": toks / max(wall, 1e-9),
        "all_completed": bool(
            len(done) == n_submitted
            and all(r.finish_reason != "failed" for r in done)),
        "outputs": {r.req_id: list(r.output) for r in done},
        "pool_drained": all(
            rh.engine.kv.pool.in_use == 0
            for rh in router.replicas
            if rh.engine is not None and rh.engine.kv is not None),
    }
    if ttft:
        out["p50_ttft_s"] = float(np.percentile(ttft, 50))
        out["p99_ttft_s"] = float(np.percentile(ttft, 99))
    return out


def make_disagg(model, params, roles, *, slots, max_len, start_down=(),
                telemetry=None, num_pages=None, **router_kw):
    base = ServeConfig(batch_slots=slots, max_len=max_len,
                       num_pages=num_pages, **_PAGED)

    def make_engine(rid):
        return ServeEngine(model, params,
                           dataclasses.replace(base, role=roles[rid]))

    return DisaggRouter(make_engine, len(roles), roles=list(roles),
                        start_down=start_down, telemetry=telemetry,
                        **router_kw)


def run(dry: bool = True, slots: int = 2, max_len: int = 64):
    cfg = dataclasses.replace(get_config("internlm2-1.8b", smoke=True),
                              num_layers=2, vocab_size=64)
    model = LM(cfg, RuntimeKnobs(cache_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))

    trace_kw = (dict(n=10, max_new=6) if dry
                else dict(n=32, max_new=24))
    reqs = trace(vocab=cfg.vocab_size, **trace_kw)
    results = {"trace": trace_kw, "slots": slots, "max_len": max_len}

    # warm the compiled steps (incl. the cross-pool page transfer) so
    # Part 1 times serving, not jit
    warm = make_disagg(model, params, ["prefill", "decode"],
                       slots=slots, max_len=max_len)
    run_router(warm, fresh(reqs[:2]))

    # ---- Part 1: disagg vs unified on a prefill-heavy trace ---------
    def make_unified(rid):
        return ServeEngine(model, params, ServeConfig(
            batch_slots=slots, max_len=max_len, **_PAGED))

    unified = run_router(
        ClusterRouter(make_unified, 2, policy="spread"), fresh(reqs))
    disagg = run_router(
        make_disagg(model, params, ["prefill", "decode"],
                    slots=slots, max_len=max_len), fresh(reqs))
    results["disagg_bitwise_identical"] = bool(
        unified["outputs"] == disagg["outputs"])
    for name, r in (("unified", unified), ("disagg", disagg)):
        results[name] = {k: r[k] for k in
                         ("requests", "tokens", "wall_s", "tok_per_s",
                          "all_completed", "pool_drained", "p50_ttft_s",
                          "p99_ttft_s") if k in r}
        print(f"{name}: {r['tokens']} tok in {r['wall_s']:.2f}s -> "
              f"{r['tok_per_s']:.1f} tok/s, ttft p50 "
              f"{r.get('p50_ttft_s', 0) * 1e3:.0f}ms / p99 "
              f"{r.get('p99_ttft_s', 0) * 1e3:.0f}ms")

    # ---- Part 2: chaos — kill a prefill replica mid-handoff ---------
    # single-slot decode replica keeps the handoff queue non-empty, so
    # the kill provably lands with chains in transit from the victim
    # slots=1 shrinks the default pool below chain + chunk headroom —
    # give the single-slot engines a 16-page pool so admission fits
    clean = run_router(
        make_disagg(model, params, ["prefill", "prefill", "decode"],
                    slots=1, max_len=max_len, num_pages=16), fresh(reqs))
    tm = Telemetry(trace=True, flight=512, flight_dir="artifacts")
    router = make_disagg(model, params, ["prefill", "prefill", "decode"],
                         slots=1, max_len=max_len, num_pages=16,
                         miss_threshold=1, telemetry=tm)
    handles = [router.submit(r) for r in fresh(reqs)]
    t0 = time.perf_counter()
    for _ in range(200):
        router.step()
        if any(h.src == 1 for h in router.handoffs):
            break
    in_flight = sum(1 for h in router.handoffs if h.src == 1)
    router.replicas[1].killed = True  # dies with handoffs in transit
    done = router.run(max_ticks=20_000)
    chaos = summarize(router, handles, done, len(reqs),
                      time.perf_counter() - t0)
    st = router.stats()
    trace_path = tm.write_trace(os.path.join("artifacts",
                                             "disagg_chaos_trace.json"))
    v = validate_chrome_trace(trace_path)
    flight_snapshot = False
    for dump in tm.flight_dumps:
        with open(dump) as f:
            payload = json.load(f)
        if payload.get("handoffs_in_transit"):
            flight_snapshot = True
    results["chaos"] = {
        k: chaos[k] for k in ("requests", "tokens", "wall_s", "tok_per_s",
                              "all_completed", "pool_drained")}
    results["chaos"].update(
        handoffs_in_transit_at_kill=in_flight,
        recoveries=st["recoveries"], failed=st["failed"],
        handoffs_done=st["handoffs_done"],
        handoff_spans=sum(1 for e in tm.trace.events
                          if e.get("ph") == "B"
                          and e.get("name") == "HANDOFF"),
        replay_spans=sum(1 for e in tm.trace.events
                         if e.get("ph") == "B"
                         and e.get("name") == "REPLAY"),
        spans_balanced=not tm.trace.open_spans(),
        trace_valid=bool(v["balanced"]),
        flight_has_handoff_snapshot=flight_snapshot,
        flight_dumps=list(tm.flight_dumps))
    results["chaos_bitwise_identical"] = bool(
        chaos["outputs"] == clean["outputs"])
    print(f"chaos: killed prefill-1 with {in_flight} handoffs in "
          f"transit; {st['recoveries']} recoveries, bitwise identical "
          f"{results['chaos_bitwise_identical']}, trace -> {trace_path}")

    # ---- Part 3a: autoscaled churn on the real stack ----------------
    tm2 = Telemetry(trace=True)
    roles = ["prefill", "prefill", "decode", "decode"]
    churn_router = make_disagg(model, params, roles, slots=slots,
                               max_len=max_len, start_down=(1, 3),
                               telemetry=tm2)
    churn_router.autoscaler = Autoscaler(
        churn_router, "queue-depth", cooldown=2, sustain=2,
        max_replicas=2, telemetry=tm2)
    churn = run_router(churn_router, fresh(reqs))
    asc = churn_router.autoscaler
    scale_spans = sum(1 for e in tm2.trace.events
                      if e.get("ph") == "B"
                      and e.get("name", "").startswith("SCALE_"))
    results["churn"] = {
        "requests": churn["requests"], "tokens": churn["tokens"],
        "all_completed": churn["all_completed"],
        "lost": int(churn_router.stats()["failed"]),
        "pool_drained": churn["pool_drained"],
        "scale_ups": asc.scale_ups, "scale_downs": asc.scale_downs,
        "scale_spans": scale_spans,
        "spans_balanced": not tm2.trace.open_spans(),
    }
    print(f"churn: {churn['requests']} served, lost "
          f"{results['churn']['lost']}, {asc.scale_ups} scale-ups / "
          f"{asc.scale_downs} scale-downs, {scale_spans} SCALE_* spans")

    # ---- Part 3b: thousands-of-requests churn via the simulator -----
    sim_trace = ([3] * 60 + [0] * 80 + [2] * 60 if dry
                 else [5] * 300 + [0] * 100 + [4] * 200)
    sim = ServeChurnSim(seed=1, trace=sim_trace, max_replicas=4,
                        cooldown=8, sustain=2)
    res = sim.run(max_ticks=50_000)
    results["sim"] = {
        "arrived": res["arrived"], "completed": res["completed"],
        "lost": res["lost"], "pending": res["pending"],
        "completed_all": bool(res["completed"] == res["arrived"]
                              and res["lost"] == 0
                              and res["pending"] == 0),
        "bounds_respected": res["bounds_respected"],
        "scale_ups": res["scale_ups"],
        "scale_downs": res["scale_downs"],
        "peak_replicas": res["peak_replicas"],
    }
    print(f"sim churn: {res['arrived']} arrived, {res['completed']} "
          f"completed, {res['scale_ups']} ups / {res['scale_downs']} "
          f"downs, peak {res['peak_replicas']}")

    emit_json("disagg_serve_dry" if dry else "disagg_serve", results)
    # headline claims, asserted in-process (machine-independent):
    assert results["disagg_bitwise_identical"], \
        "disagg outputs diverged from the unified pool"
    assert unified["all_completed"] and disagg["all_completed"]
    assert disagg["pool_drained"], "disagg run leaked KV pages"
    assert chaos["all_completed"], \
        "requests were lost to the mid-handoff kill"
    assert results["chaos_bitwise_identical"], \
        "post-kill continuations diverged from the fault-free twin"
    assert results["chaos"]["recoveries"] >= 1, \
        "the chaos kill recovered nothing — the gate tested nothing"
    assert chaos["pool_drained"], \
        "surviving replicas leaked KV pages after the mid-handoff kill"
    assert results["chaos"]["handoff_spans"] >= 1
    assert results["chaos"]["spans_balanced"], \
        "chaos run left trace spans open"
    assert results["chaos"]["trace_valid"]
    assert results["churn"]["lost"] == 0, "autoscaled churn lost requests"
    assert results["churn"]["pool_drained"], \
        "autoscaled churn left pages in a pool"
    assert results["churn"]["scale_ups"] >= 1, \
        "churn backlog never woke a cold spare"
    assert results["churn"]["scale_spans"] >= 1, \
        "scale events left no telemetry spans"
    assert results["churn"]["spans_balanced"]
    assert results["sim"]["completed_all"], "simulator churn lost requests"
    assert results["sim"]["bounds_respected"], \
        "simulator let a role leave its min/max bounds"
    assert results["sim"]["scale_ups"] >= 1 \
        and results["sim"]["scale_downs"] >= 1, \
        "simulator churn failed to exercise both scale directions"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="fast CI mode: tiny trace")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()
    run(dry=args.dry, slots=args.slots, max_len=args.max_len)


if __name__ == "__main__":
    main()
