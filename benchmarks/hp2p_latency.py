"""Paper Fig 7 — HP2P (communication-intensive) latency vs cluster size.

The paper spreads 32 MPI ranks over 2..6 hosts and sees average latency
rise ~10% until 4 hosts, then plateau.  TPU analogue: a fixed 8-chip
all-reduce job spread over 2..6 hosts of a 2-pod cluster (3 hosts/pod).
Once the spread crosses the pod boundary the ring all-reduce pays DCN —
and a ring has exactly TWO cut edges regardless of how it is split, so
further spreading neither helps nor hurts: the paper's plateau.
"""
from __future__ import annotations

from repro.core import hw
from repro.core.jobs import RooflineProfile

from .common import emit, save_artifact

HOSTS_PER_POD = 3


def hp2p_step_s(hosts: int, payload: float) -> dict:
    """Ring all-reduce latency for 8 chips spread over ``hosts`` hosts."""
    chips = 8
    ici_s = 2.0 * payload / (chips * hw.ICI_BW)  # ~2x payload moved
    pod0 = min(hosts, HOSTS_PER_POD)
    pod1 = hosts - pod0
    if pod1 > 0:
        # ring cut: 2 edges cross DCN; each carries the full reduced payload
        dcn_s = 2.0 * payload / (2 * hw.DCN_BW_PER_HOST)
    else:
        dcn_s = 0.0
    return {"hosts": hosts, "pods": 1 + (pod1 > 0), "ici_s": ici_s,
            "dcn_s": dcn_s, "step_s": ici_s + dcn_s}


def run():
    payload = 2048e6 * 20 / 32  # paper: 2048 MB x 20 iters over 32 ranks
    rows = [hp2p_step_s(h, payload) for h in (2, 3, 4, 5, 6)]
    for r in rows:
        emit(f"fig7_hp2p_hosts{r['hosts']}", r["step_s"] * 1e6,
             f"pods={r['pods']} dcn={r['dcn_s']:.4f}s")
    one_pod = [r for r in rows if r["pods"] == 1]
    two_pod = [r for r in rows if r["pods"] == 2]
    assert two_pod[0]["step_s"] > one_pod[-1]["step_s"], \
        "crossing the pod boundary must cost latency (paper Fig 7 rise)"
    spread_delta = abs(two_pod[-1]["step_s"] - two_pod[0]["step_s"])
    assert spread_delta / two_pod[0]["step_s"] < 0.15, \
        "latency must plateau once spread (paper Fig 7 plateau)"
    save_artifact("bench_fig7.json", rows)


if __name__ == "__main__":
    run()
