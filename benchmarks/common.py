"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import json
import os
import time


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return dt, out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def save_artifact(name: str, payload):
    os.makedirs("artifacts", exist_ok=True)
    with open(os.path.join("artifacts", name), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def load_dryrun_rows(path="artifacts/roofline.json"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)
