"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

_REGISTRY = None


def registry():
    """The benchmark process's shared ``MetricsRegistry`` (lazy import —
    callers put ``src/`` on ``sys.path`` before the first call).  Section
    wall times, roofline fractions, etc. all land here, so a benchmark's
    timing report is a registry read, not a second stopwatch."""
    global _REGISTRY
    if _REGISTRY is None:
        from repro.runtime.telemetry import MetricsRegistry
        _REGISTRY = MetricsRegistry()
    return _REGISTRY


@contextmanager
def section(name: str):
    """Time one benchmark section into the
    ``bench_section_seconds{section=...}`` gauge.  Reports read back via
    ``section_times()`` — the registry is the one source of wall time."""
    reg = registry()
    t0 = time.perf_counter()
    try:
        yield
    finally:
        reg.gauge("bench_section_seconds",
                  "wall seconds per benchmark section",
                  ("section",)).labels(section=name).set(
            time.perf_counter() - t0)


def section_times() -> dict:
    """{section: wall seconds} read from the registry."""
    fam = registry().to_dict().get("bench_section_seconds")
    if fam is None:
        return {}
    return {s["labels"]["section"]: s["value"] for s in fam["series"]}


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return dt, out


def request_latency_stats(reqs) -> dict:
    """p50/p99 aggregation of ``runtime.serve.request_metrics`` (TTFT and
    TPOT) over a batch of served requests."""
    import numpy as np

    from repro.runtime.serve import request_metrics

    ms = [request_metrics(r) for r in reqs]
    out = {}
    for key in ("ttft_s", "tpot_s"):
        vals = [m[key] for m in ms if key in m]
        if vals:
            out[f"p50_{key}"] = float(np.percentile(vals, 50))
            out[f"p99_{key}"] = float(np.percentile(vals, 99))
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def emit_json(name: str, payload: dict, root: str = "."):
    """CSV line per scalar metric (same stream the other figures use) plus a
    BENCH_<name>.json snapshot so trajectories can be tracked across PRs."""
    for k, v in payload.items():
        if isinstance(v, (int, float)):
            # %.6g, not emit()'s %.1f: latency metrics are well under 0.05
            print(f"{name}_{k},{float(v):.6g},{k}")
    path = os.path.join(root, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {path}")
    return path


def save_artifact(name: str, payload):
    os.makedirs("artifacts", exist_ok=True)
    with open(os.path.join("artifacts", name), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def load_dryrun_rows(path="artifacts/roofline.json"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)
