# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Each module also asserts the paper's qualitative claim (trend
# or win direction) so `python -m benchmarks.run` doubles as a reproduction
# gate.  Figure mapping: see DESIGN.md §6.
from __future__ import annotations

import sys
import traceback

from . import (container_overhead, cosched_utilization, hp2p_latency,
               kernel_micro, minife_scaling, policy_comparison,
               serve_throughput)

BENCHES = [
    ("fig5_container_overhead", container_overhead.run),
    ("fig6_minife_scaling", minife_scaling.run),
    ("fig7_hp2p_latency", hp2p_latency.run),
    ("fig8_11_cosched_utilization", cosched_utilization.run),
    ("fig12_13_policy_comparison", policy_comparison.run),
    ("kernel_microbench", kernel_micro.run),
    ("serve_throughput", serve_throughput.run),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for name, fn in BENCHES:
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        sys.exit(1)
    print("all_benches,0.0,ok")


if __name__ == "__main__":
    main()
