"""Microbenchmarks of the compute layers the scheduler places (real timings
on this host, interpret-mode kernels excluded — XLA paths only)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.models.attention import decode_attention_xla, flash_attention_xla
from repro.models.ssm import ssm_forward, ssm_init

from .common import emit, timed


def run():
    rng = np.random.default_rng(0)

    def arr(*s):
        return jnp.asarray(rng.normal(size=s), jnp.float32)

    b, h, kv, s, d = 1, 4, 2, 1024, 64
    q, k, v = arr(b, s, h, d), arr(b, s, kv, d), arr(b, s, kv, d)
    fa = jax.jit(lambda q, k, v: flash_attention_xla(q, k, v, causal=True,
                                                     q_chunk=256))
    dt, _ = timed(lambda: fa(q, k, v).block_until_ready())
    flops = 4 * b * h * s * s * d
    emit("flash_attention_xla_1k", dt * 1e6,
         f"{flops / dt / 1e9:.1f} GFLOP/s host")

    faw = jax.jit(lambda q, k, v: flash_attention_xla(
        q, k, v, causal=True, window=128, q_chunk=256))
    dtw, _ = timed(lambda: faw(q, k, v).block_until_ready())
    emit("flash_attention_xla_1k_win128", dtw * 1e6,
         f"windowed speedup x{dt / dtw:.2f} (sub-quadratic slicing)")

    qd = arr(b, 1, h, d)
    kc, vc = arr(b, 8192, kv, d), arr(b, 8192, kv, d)
    da = jax.jit(lambda q, k, v: decode_attention_xla(q, k, v, 8000))
    dtd, _ = timed(lambda: da(qd, kc, vc).block_until_ready())
    gb = 2 * 8192 * kv * d * 4 / 1e9
    emit("decode_attention_xla_8k", dtd * 1e6,
         f"{gb / dtd:.2f} GB/s cache stream host")

    cfg = SSMConfig(d_state=16, head_dim=16, expand=2, chunk_size=64)
    dm = 64
    params = ssm_init(jax.random.PRNGKey(0), dm, cfg)
    x = arr(2, 1024, dm)
    fs = jax.jit(lambda x: ssm_forward(params, x, dm, cfg))
    dts, _ = timed(lambda: fs(x).block_until_ready())
    emit("ssd_chunked_1k", dts * 1e6,
         f"{2 * 1024 / dts / 1e6:.2f} Mtok/s host")


if __name__ == "__main__":
    run()
